#!/usr/bin/env python3
"""Executable spec of the packed-GEMM index math and accumulation order.

A 1:1 stdlib-only port of ``rust/src/linalg/gemm.rs``'s packing layer:

* ``partition`` / ``partition_aligned`` — the engine's chunk planner
  (``rust/src/exec/cost.rs``), including the MC-grid alignment the packed
  driver requests;
* ``pack_a`` / ``pack_b`` — MR-row column-major and NR-column row-major
  micro-panel layouts, both operand orientations (the transposing packs
  used by gemm_tn / gemm_nt), with zero padding of short panels;
* ``micro_full`` / ``micro_edge`` — the register micro-kernel's strictly
  ascending-k accumulation chains;
* ``run_rows`` — the NC → KC → MC → NR → MR loop nest.

Python floats are IEEE-754 doubles with the same ``+``/``*`` semantics the
Rust kernel relies on (no FMA contraction, no reassociation), so the
determinism contract is checkable here bit for bit: every variant, every
shape, every chunk split must equal the naive i-j-l triple loop exactly.
CI runs this before building the Rust tree; a failure means the documented
contract and the spec disagree.
"""

import struct

# Tuning constants — keep in lockstep with rust/src/linalg/gemm.rs.
MR, NR = 4, 8
MC, KC, NC = 64, 256, 512


# --- Chunk planner (rust/src/exec/cost.rs) --------------------------------

def partition(n, parts):
    if n == 0:
        return []
    parts = max(1, min(parts, n))
    base, rem = divmod(n, parts)
    out, start = [], 0
    for i in range(parts):
        length = base + (1 if i < rem else 0)
        out.append((start, start + length))
        start += length
    return out


def ceil_div(a, b):
    return -(-a // b)


def partition_aligned(n, parts, align):
    align = max(1, align)
    if align == 1:
        return partition(n, parts)
    blocks = ceil_div(n, align)
    return [(s * align, min(e * align, n)) for s, e in partition(blocks, parts)]


# --- Packing (gemm.rs pack_a / pack_b) ------------------------------------
# Operands are flat row-major lists. ``trans=False`` mirrors AView::Rows /
# BView::Rows; ``trans=True`` mirrors the transposing Cols variants.

def pack_a(a, ld, i0, mc, k0, kcw, trans):
    panels = ceil_div(mc, MR)
    out = [0.0] * (panels * MR * kcw)
    for p in range(panels):
        rows = min(mc - p * MR, MR)
        base = p * MR * kcw
        if not trans:
            for r in range(rows):
                row0 = (i0 + p * MR + r) * ld + k0
                for kk in range(kcw):
                    out[base + kk * MR + r] = a[row0 + kk]
        else:
            for kk in range(kcw):
                src0 = (k0 + kk) * ld + i0 + p * MR
                for r in range(rows):
                    out[base + kk * MR + r] = a[src0 + r]
    return out


def pack_b(b, ld, k0, kcw, j0, nc, trans):
    panels = ceil_div(nc, NR)
    out = [0.0] * (panels * NR * kcw)
    for p in range(panels):
        cols = min(nc - p * NR, NR)
        base = p * NR * kcw
        if not trans:
            for kk in range(kcw):
                src0 = (k0 + kk) * ld + j0 + p * NR
                for c in range(cols):
                    out[base + kk * NR + c] = b[src0 + c]
        else:
            for c in range(cols):
                row0 = (j0 + p * NR + c) * ld + k0
                for kk in range(kcw):
                    out[base + kk * NR + c] = b[row0 + kk]
    return out


# --- Micro-kernels (exact accumulation order) -----------------------------

def micro_full(ap, bp, c, off, ldc, kcw):
    acc = [[c[off + r * ldc + j] for j in range(NR)] for r in range(MR)]
    for kk in range(kcw):
        a4 = ap[kk * MR:(kk + 1) * MR]
        b8 = bp[kk * NR:(kk + 1) * NR]
        for r in range(MR):
            ar = a4[r]
            accr = acc[r]
            for j in range(NR):
                accr[j] += ar * b8[j]
    for r in range(MR):
        for j in range(NR):
            c[off + r * ldc + j] = acc[r][j]


def micro_edge(ap, bp, c, off, ldc, rows, cols, kcw):
    for r in range(rows):
        for j in range(cols):
            s = c[off + r * ldc + j]
            for kk in range(kcw):
                s += ap[kk * MR + r] * bp[kk * NR + j]
            c[off + r * ldc + j] = s


# --- Blocked driver (gemm.rs Packed::run_rows) ----------------------------

def run_rows(a, ald, a_trans, b, bld, b_trans, k, n, c_rows, r0, r1):
    for j0 in range(0, n, NC):
        nc = min(n - j0, NC)
        b_panels = ceil_div(nc, NR)
        for k0 in range(0, k, KC):
            kcw = min(k - k0, KC)
            bp = pack_b(b, bld, k0, kcw, j0, nc, b_trans)
            for i0 in range(r0, r1, MC):
                mc = min(r1 - i0, MC)
                a_panels = ceil_div(mc, MR)
                ap = pack_a(a, ald, i0, mc, k0, kcw, a_trans)
                for q in range(b_panels):
                    cols = min(nc - q * NR, NR)
                    bpp = bp[q * NR * kcw:(q + 1) * NR * kcw]
                    for p in range(a_panels):
                        rows = min(mc - p * MR, MR)
                        app = ap[p * MR * kcw:(p + 1) * MR * kcw]
                        off = (i0 - r0 + p * MR) * n + j0 + q * NR
                        if rows == MR and cols == NR:
                            micro_full(app, bpp, c_rows, off, n, kcw)
                        else:
                            micro_edge(app, bpp, c_rows, off, n, rows, cols, kcw)


def packed_gemm(a, b, m, k, n, a_trans=False, b_trans=False, parts=1):
    """C = A·B over a ``parts``-way MC-aligned row split, like the engine.

    ``a_trans`` means ``a`` is the k x m buffer of gemm_tn; ``b_trans``
    means ``b`` is the n x k buffer of gemm_nt.
    """
    ald = m if a_trans else k
    bld = k if b_trans else n
    c = [0.0] * (m * n)
    for r0, r1 in partition_aligned(m, parts, MC):
        rows = c[r0 * n:r1 * n]
        run_rows(a, ald, a_trans, b, bld, b_trans, k, n, rows, r0, r1)
        c[r0 * n:r1 * n] = rows
    return c


def naive_gemm(a, b, m, k, n):
    """The contract's reference order: one ascending-l chain per element."""
    c = [0.0] * (m * n)
    for i in range(m):
        for j in range(n):
            s = 0.0
            for l in range(k):
                s += a[i * k + l] * b[l * n + j]
            c[i * n + j] = s
    return c


# --- Deterministic data ----------------------------------------------------

def lcg_data(count, seed):
    x = seed & 0xFFFFFFFFFFFFFFFF
    out = []
    for _ in range(count):
        x = (x * 6364136223846793005 + 1442695040888963407) & 0xFFFFFFFFFFFFFFFF
        out.append(((x >> 11) / float(1 << 53)) * 2.0 - 1.0)
    return out


def bits(vec):
    return struct.pack("<%dd" % len(vec), *vec)


def transpose(a, rows, cols):
    return [a[i * cols + j] for j in range(cols) for i in range(rows)]


# --- Checks ----------------------------------------------------------------

def check_partitions():
    for n in (0, 1, 7, 63, 64, 65, 129, 1000):
        for parts in (1, 2, 3, 8):
            ranges = partition(n, parts)
            flat = [x for r in ranges for x in r]
            # Contiguous cover of [0, n), all ranges non-empty.
            assert flat == sorted(flat), (n, parts)
            assert all(e > s for s, e in ranges), (n, parts)
            assert (not ranges and n == 0) or (ranges[0][0] == 0 and ranges[-1][1] == n)
            for align in (1, 64):
                ar = partition_aligned(n, parts, align)
                assert all(s % align == 0 for s, _ in ar), (n, parts, align)
                assert all(e % align == 0 or e == n for _, e in ar), (n, parts, align)
                assert (not ar and n == 0) or (ar[0][0] == 0 and ar[-1][1] == n)
            assert partition_aligned(n, parts, 1) == ranges
    print("partition/partition_aligned: boundaries on the grid, full cover")


def check_shapes():
    shapes = [
        (65, 17, 24),    # straddles MC, partial everything
        (8, 257, 16),    # straddles KC
        (12, 20, 513),   # straddles NC
        (5, 9, 11),      # partial MR and NR tiles
        (4, 8, 8),       # one exact micro-tile stack
        (3, 4, 7),       # below both micro-tile dims
        (1, 1, 1),       # degenerate
    ]
    for m, k, n in shapes:
        a = lcg_data(m * k, seed=m * 1_000_003 + k * 97 + n)
        b = lcg_data(k * n, seed=n * 1_000_033 + k * 89 + m)
        want = bits(naive_gemm(a, b, m, k, n))
        for parts in (1, 2, 3, 5):
            got = bits(packed_gemm(a, b, m, k, n, parts=parts))
            assert got == want, f"nn bits differ at {m}x{k}x{n} parts={parts}"
        # Transposing packs read the same scalars in the same order.
        at = transpose(a, m, k)  # k x m buffer, gemm_tn operand
        assert bits(packed_gemm(at, b, m, k, n, a_trans=True)) == want, \
            f"tn bits differ at {m}x{k}x{n}"
        bt = transpose(b, k, n)  # n x k buffer, gemm_nt operand
        assert bits(packed_gemm(a, bt, m, k, n, b_trans=True)) == want, \
            f"nt bits differ at {m}x{k}x{n}"
        print(f"{m:>3} x {k:>3} x {n:>3}: nn/tn/nt bitwise == naive, "
              "chunk-split invariant")


def main():
    check_partitions()
    check_shapes()
    print("pack_sim: all packing-order invariants hold")


if __name__ == "__main__":
    main()
