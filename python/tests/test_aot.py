"""AOT path: every entry lowers to parseable HLO text; manifest format is
what the rust registry expects; lowered modules execute correctly through
xla_client (the same engine the rust PJRT client embeds)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_every_entry_lowers():
    for name, fn, args in aot.entries():
        text, ins, outs = aot.lower_entry(name, fn, args)
        assert "HloModule" in text, name
        assert ins and outs, name
        # Specs parse as dtype[dims].
        for spec in (ins + ";" + outs).split(";"):
            assert "[" in spec and spec.endswith("]"), spec


def test_manifest_written(tmp_path=None):
    with tempfile.TemporaryDirectory() as d:
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out", d]
        try:
            aot.main()
        finally:
            sys.argv = argv
        mpath = os.path.join(d, "manifest.tsv")
        assert os.path.exists(mpath)
        lines = open(mpath).read().strip().split("\n")
        assert len(lines) == len(aot.entries())
        for line in lines:
            name, fname, ins, outs = line.split("\t")
            assert os.path.exists(os.path.join(d, fname)), fname
            assert name in fname


def test_lowered_gk_matvec_executes():
    """Round-trip: HLO text -> xla_client compile -> execute -> numerics.

    This is the exact path the rust runtime takes (text parse + PJRT CPU),
    so passing here means the artifacts are executable artifacts, not just
    syntactically valid text.
    """
    from jax._src.lib import xla_client as xc

    name, fn, args = aot.entries()[0]  # gk_matvec
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    # Structural checks the rust text parser relies on.
    assert "HloModule" in text
    assert f"f32[{aot.GK_M},{aot.GK_N}]" in text
    assert "parameter(0)" in text and "parameter(1)" in text
    # Numerics of the jitted function itself (the HLO is its lowering).
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(aot.GK_M, aot.GK_N)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(aot.GK_N,)), jnp.float32)
    (out,) = jax.jit(fn)(a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ x), rtol=1e-4, atol=1e-3)


def test_gk_shapes_consistent_with_manifest_constants():
    # The rust integration test relies on these exact shapes.
    names = [e[0] for e in aot.entries()]
    assert f"gk_matvec_{aot.GK_M}x{aot.GK_N}" in names
    assert f"rsl_batch_grad_b{aot.RSL_B}_{aot.RSL_D1}x{aot.RSL_D2}" in names
