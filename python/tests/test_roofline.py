"""Roofline model sanity: every shipped kernel fits VMEM and the model's
byte/flop accounting is self-consistent."""

from compile import roofline


def test_all_kernels_fit_vmem():
    for km in roofline.models():
        assert km.vmem_per_step < roofline.VMEM_BYTES, km.name


def test_intensity_positive_and_bounds_sane():
    for km in roofline.models():
        assert km.intensity > 0
        assert km.bound in ("compute", "memory")
        assert km.time_bound_us > 0


def test_memory_bound_kernels():
    by_name = {km.name: km for km in roofline.models()}
    # The GK hot products are memory-bound by construction (AI ~ 0.5).
    assert by_name["gemv"].bound == "memory"
    assert by_name["gemv_t"].bound == "memory"
    assert by_name["reorth"].bound == "memory"
    # gemm has far higher arithmetic intensity than the gemv family.
    assert by_name["gemm"].intensity > 10 * by_name["gemv"].intensity


def test_grid_covers_shape():
    gm = roofline.gemv_model(1024, 512)
    assert gm.grid[0] * gm.grid[1] >= 1
    # exact divisor tiling
    rm = roofline.reorth_model(1024, 64)
    assert rm.grid == (2, 2)
