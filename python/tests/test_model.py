"""L2 correctness: model entry points vs ref.py, shapes, and the hinge
gradient against jax autodiff (the strongest oracle available)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(777)


def _arr(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


def test_gk_matvec_entries():
    a = _arr((128, 96))
    p = _arr((96,))
    q = _arr((128,))
    (out,) = model.gk_matvec(a, p)
    np.testing.assert_allclose(out, ref.gemv(a, p), rtol=1e-4, atol=1e-4)
    (out_t,) = model.gk_matvec_t(a, q)
    np.testing.assert_allclose(out_t, ref.gemv_t(a, q), rtol=1e-4, atol=1e-4)


def test_gk_step_fuses_lines_5_and_6():
    m, n, k = 128, 96, 8
    a = _arr((m, n))
    p_j = _arr((n,))
    q_j = _arr((m,))
    alpha = jnp.float32(1.7)
    q_basis = jnp.asarray(np.linalg.qr(RNG.normal(size=(m, k)))[0], jnp.float32)
    (got,) = model.gk_step(a, p_j, q_j, alpha, q_basis)
    want = ref.reorth(q_basis, ref.gemv(a, p_j) - alpha * q_j)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gk_step_tolerates_zero_padded_basis():
    # The rust runtime pads Q with zero columns up to the artifact's k.
    m, n, k = 128, 96, 8
    a = _arr((m, n))
    p_j = _arr((n,))
    q_j = _arr((m,))
    alpha = jnp.float32(0.5)
    q2 = np.linalg.qr(RNG.normal(size=(m, 3)))[0]
    padded = np.zeros((m, k), np.float32)
    padded[:, :3] = q2
    (got,) = model.gk_step(a, p_j, q_j, alpha, jnp.asarray(padded))
    (want,) = model.gk_step(a, p_j, q_j, alpha, jnp.asarray(q2, jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rsl_batch_grad_matches_ref():
    w = _arr((784, 256), 0.05)
    xb = _arr((32, 784))
    vb = _arr((32, 256))
    y = jnp.asarray(RNG.choice([-1.0, 1.0], size=32), jnp.float32)
    lam = jnp.float32(1e-3)
    gr, loss = model.rsl_batch_grad(w, xb, vb, y, lam)
    gr_ref, loss_ref = ref.rsl_batch_grad(w, xb, vb, y, lam)
    np.testing.assert_allclose(loss, loss_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gr, gr_ref, rtol=1e-3, atol=1e-4)


def test_rsl_grad_matches_autodiff():
    # Hinge is non-smooth at the kink; keep margins away from it.
    w = _arr((64, 48), 0.01)
    xb = _arr((16, 64))
    vb = _arr((16, 48))
    y = jnp.asarray(RNG.choice([-1.0, 1.0], size=16), jnp.float32)
    lam = 1e-2

    def objective(wm):
        f = ref.rsl_scores(wm, xb, vb)
        return jnp.mean(ref.hinge_loss(f, y)) + 0.5 * lam * jnp.sum(wm * wm) / 1.0

    # Note: our Gr uses lam*W (derivative of 0.5*lam*||W||^2).
    auto = jax.grad(objective)(w)
    gr, _ = model.rsl_batch_grad(w, xb, vb, y, jnp.float32(lam))
    np.testing.assert_allclose(np.asarray(gr), np.asarray(auto), rtol=1e-3, atol=1e-4)


def test_rsl_scores_entry_shape():
    w = _arr((784, 256), 0.05)
    xb = _arr((32, 784))
    vb = _arr((32, 256))
    (f,) = model.rsl_scores(w, xb, vb)
    assert f.shape == (32,)
    np.testing.assert_allclose(f, ref.rsl_scores(w, xb, vb), rtol=1e-3, atol=1e-3)
