"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes (and dtypes where the MXU contract allows bf16) so
the BlockSpec tiling logic is exercised across non-divisible, degenerate
and large-block shapes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bilinear, gemm, gemv, reorth, ref

RNG = np.random.default_rng(12345)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


def _close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# ---------------------------------------------------------------- gemv


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    bm=st.sampled_from([8, 64, 256]),
    bn=st.sampled_from([8, 128, 512]),
)
def test_gemv_matches_ref(m, n, bm, bn):
    a = _arr((m, n))
    x = _arr((n,))
    _close(gemv.gemv(a, x, block_m=bm, block_n=bn), ref.gemv(a, x))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    bm=st.sampled_from([8, 64, 512]),
    bn=st.sampled_from([8, 128, 256]),
)
def test_gemv_t_matches_ref(m, n, bm, bn):
    a = _arr((m, n))
    y = _arr((m,))
    _close(gemv.gemv_t(a, y, block_m=bm, block_n=bn), ref.gemv_t(a, y))


def test_gemv_prime_dims():
    # 127 and 251 are prime: exercises the divisor-search fallback to 1.
    a = _arr((127, 251))
    x = _arr((251,))
    y = _arr((127,))
    _close(gemv.gemv(a, x), ref.gemv(a, x))
    _close(gemv.gemv_t(a, y), ref.gemv_t(a, y))


# ---------------------------------------------------------------- gemm


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 128),
    k=st.integers(1, 128),
    n=st.integers(1, 128),
)
def test_gemm_matches_ref(m, k, n):
    a = _arr((m, k))
    b = _arr((k, n))
    _close(gemm.gemm(a, b), ref.gemm(a, b), tol=1e-3)


def test_gemm_bf16_accumulates_in_f32():
    a = _arr((64, 64), jnp.bfloat16)
    b = _arr((64, 64), jnp.bfloat16)
    out = gemm.gemm(a, b)
    assert out.dtype == jnp.float32
    # bf16 inputs: loose tolerance band.
    want = jnp.asarray(a, jnp.float32) @ jnp.asarray(b, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=0.1, atol=0.5)


def test_gemm_block_sweep():
    a = _arr((96, 80))
    b = _arr((80, 112))
    want = ref.gemm(a, b)
    for bm, bn, bk in [(8, 8, 8), (32, 16, 80), (96, 112, 40)]:
        _close(gemm.gemm(a, b, block_m=bm, block_n=bn, block_k=bk), want, tol=1e-3)


# ---------------------------------------------------------------- reorth


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(4, 400),
    k=st.integers(1, 32),
    bm=st.sampled_from([16, 128, 512]),
)
def test_reorth_matches_ref(m, k, bm):
    if k > m:
        k = m
    q_full, _ = np.linalg.qr(RNG.normal(size=(m, k)))
    q = jnp.asarray(q_full, jnp.float32)
    w = _arr((m,))
    _close(reorth.reorth(q, w, block_m=bm), ref.reorth(q, w))


def test_reorth_orthogonal_output():
    # After one CGS pass against an orthonormal Q, Q^T w ~ 0.
    m, k = 256, 16
    q = jnp.asarray(np.linalg.qr(RNG.normal(size=(m, k)))[0], jnp.float32)
    w = _arr((m,))
    out = reorth.reorth(q, w)
    resid = np.abs(np.asarray(q.T @ out)).max()
    assert resid < 1e-4, resid


def test_reorth_zero_basis_is_identity():
    # Zero columns contribute nothing (the gk_step padding contract).
    q = jnp.zeros((128, 8), jnp.float32)
    w = _arr((128,))
    _close(reorth.reorth(q, w), w)


# ---------------------------------------------------------------- bilinear


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 48),
    d1=st.integers(2, 256),
    d2=st.integers(2, 200),
)
def test_rsl_scores_matches_ref(b, d1, d2):
    w = _arr((d1, d2), scale=0.1)
    xb = _arr((b, d1))
    vb = _arr((b, d2))
    _close(bilinear.rsl_scores(w, xb, vb), ref.rsl_scores(w, xb, vb), tol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 48),
    d1=st.integers(2, 256),
    d2=st.integers(2, 200),
)
def test_rsl_grad_core_matches_ref(b, d1, d2):
    xb = _arr((b, d1))
    vb = _arr((b, d2))
    g = _arr((b,))
    want = (xb * g[:, None]).T @ vb
    _close(bilinear.rsl_grad_core(xb, g, vb), want, tol=1e-3)


def test_paper_shapes_exactly():
    # The shipped artifact shapes: b=32, d1=784, d2=256.
    w = _arr((784, 256), scale=0.05)
    xb = _arr((32, 784))
    vb = _arr((32, 256))
    _close(bilinear.rsl_scores(w, xb, vb), ref.rsl_scores(w, xb, vb), tol=1e-3)
