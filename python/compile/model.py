"""L2 — JAX compute graphs for the paper's system, calling the L1 Pallas
kernels. Build-time only: `aot.py` lowers each entry point once to HLO
text; the rust coordinator executes the compiled artifacts on the request
path and Python is never invoked again.

Entry points (all f32, fixed shapes chosen by aot.py):

* `gk_matvec` / `gk_matvec_t` — the Golub-Kahan hot products A@p / A.T@q
  (Algorithm 1 lines 5/12).
* `gk_reorth` — one classical Gram-Schmidt pass (lines 6/13).
* `gk_step` — a fused Algorithm-1 iteration half: A@p - alpha*q followed
  by reorthogonalization (what the rust `runtime::backend` calls when an
  artifact with matching shape exists).
* `rsl_scores` / `rsl_batch_grad` — the RSL application's forward scores
  and Euclidean batch gradient (Algorithm 4 lines 5-6).
"""

import jax.numpy as jnp

from .kernels import bilinear as _bilinear
from .kernels import gemv as _gemv
from .kernels import reorth as _reorth


def gk_matvec(a, p):
    """A @ p (Algorithm 1 line 5 product)."""
    return (_gemv.gemv(a, p),)


def gk_matvec_t(a, q):
    """A.T @ q (Algorithm 1 line 12 product)."""
    return (_gemv.gemv_t(a, q),)


def gk_reorth(q_basis, w):
    """w - Q (Q^T w): one CGS pass (Algorithm 1 lines 6/13)."""
    return (_reorth.reorth(q_basis, w),)


def gk_step(a, p_j, q_j, alpha_j, q_basis):
    """Fused Algorithm 1 lines 5-6: candidate q_{k'+1} before normalization.

    q_new = A @ p_j - alpha_j * q_j, then one reorthogonalization pass
    against the current basis Q (zero columns beyond k' are harmless:
    they contribute nothing to Q Q^T w).
    """
    q_new = _gemv.gemv(a, p_j) - alpha_j * q_j
    return (_reorth.reorth(q_basis, q_new),)


def rsl_scores(w, xb, vb):
    """Batched bilinear scores (paper eq. 19)."""
    return (_bilinear.rsl_scores(w, xb, vb),)


def rsl_batch_grad(w, xb, vb, y, lam):
    """Batch Euclidean gradient of the regularized hinge objective.

    Returns (Gr, mean_loss); mirrors `ref.rsl_batch_grad` and the rust
    native engine exactly (same sign conventions).
    """
    f = _bilinear.rsl_scores(w, xb, vb)
    margin = 1.0 - y * f
    loss = jnp.mean(jnp.maximum(0.0, margin))
    g = jnp.where(margin > 0.0, -y, 0.0) / xb.shape[0]
    gr = _bilinear.rsl_grad_core(xb, g, vb) + lam * w
    return gr, loss
