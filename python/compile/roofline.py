"""L1 §Perf: analytic VMEM-footprint / roofline model for the Pallas kernels.

interpret=True gives CPU-numpy timings that say nothing about TPU
performance, so (per DESIGN.md §Hardware-Adaptation) the L1 perf evidence
is *structural*: for each kernel at its shipped artifact shape we compute,
from the BlockSpec tiling itself,

  * VMEM footprint per grid step (must sit well under ~16 MiB/core),
  * bytes moved HBM<->VMEM over the whole grid,
  * FLOPs, arithmetic intensity (FLOP/byte),
  * the roofline-implied bound on a v4-like core
    (275 TFLOP/s bf16 MXU, 1.2 TB/s HBM) and which wall binds.

Run: cd python && python -m compile.roofline       (writes ../results/l1_roofline.csv)
"""

import csv
import os
from dataclasses import dataclass

# v4-ish single-core numbers; only ratios matter for "which wall binds".
PEAK_FLOPS = 275e12  # bf16 MXU
PEAK_BW = 1.2e12     # HBM bytes/s
VMEM_BYTES = 16 * 1024 * 1024


@dataclass
class KernelModel:
    name: str
    # per-grid-step VMEM residency (bytes)
    vmem_per_step: int
    # totals over the full grid
    hbm_bytes: int
    flops: int
    grid: tuple

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)

    @property
    def bound(self) -> str:
        # ridge point of the roofline
        return "compute" if self.intensity > PEAK_FLOPS / PEAK_BW else "memory"

    @property
    def time_bound_us(self) -> float:
        return max(self.flops / PEAK_FLOPS, self.hbm_bytes / PEAK_BW) * 1e6


def _blk(dim, want):
    b = min(want, dim)
    while dim % b != 0:
        b -= 1
    return b


def gemv_model(m, n, bm=256, bn=512, dtype=4):
    bm, bn = _blk(m, bm), _blk(n, bn)
    grid = (m // bm, n // bn)
    # per step: A tile + x block + y block
    vmem = (bm * bn + bn + bm) * dtype
    # A streamed once; x re-read per row-block; y written once per column
    # pass (accumulated in place).
    hbm = (m * n + (m // bm) * n + m) * dtype
    return KernelModel("gemv", vmem, hbm, 2 * m * n, grid)


def gemv_t_model(m, n, bm=512, bn=256, dtype=4):
    bm, bn = _blk(m, bm), _blk(n, bn)
    grid = (n // bn, m // bm)
    vmem = (bm * bn + bm + bn) * dtype
    hbm = (m * n + (n // bn) * m + n) * dtype
    return KernelModel("gemv_t", vmem, hbm, 2 * m * n, grid)


def reorth_model(m, k, bm=512, dtype=4):
    bm = _blk(m, bm)
    grid = (2, m // bm)
    vmem = (bm * k + bm + k) * dtype
    # Q streamed twice (phase 0 + phase 1), w twice, out once, c negligible.
    hbm = (2 * m * k + 3 * m) * dtype
    return KernelModel("reorth", vmem, hbm, 4 * m * k, grid)


def gemm_model(m, k, n, bm=128, bn=128, bk=256, dtype=4):
    bm, bn, bk = _blk(m, bm), _blk(n, bn), _blk(k, bk)
    grid = (m // bm, n // bn, k // bk)
    vmem = (bm * bk + bk * bn + bm * bn) * dtype
    # A re-read per n-block, B per m-block, C written once.
    hbm = ((n // bn) * m * k + (m // bm) * k * n + m * n) * dtype
    return KernelModel("gemm", vmem, hbm, 2 * m * k * n, grid)


def rsl_scores_model(b, d1, d2, bd1=256, dtype=4):
    bd1 = _blk(d1, bd1)
    grid = (d1 // bd1,)
    vmem = (b * bd1 + bd1 * d2 + b * d2 + b) * dtype
    hbm = (b * d1 + d1 * d2 + (d1 // bd1) * b * d2 + b) * dtype
    return KernelModel("rsl_scores", vmem, hbm, 2 * b * d1 * d2, grid)


def rsl_grad_model(b, d1, d2, bd1=256, bd2=256, dtype=4):
    bd1, bd2 = _blk(d1, bd1), _blk(d2, bd2)
    grid = (d1 // bd1, d2 // bd2)
    vmem = (b * bd1 + b + b * bd2 + bd1 * bd2) * dtype
    hbm = ((d2 // bd2) * b * d1 + (d1 // bd1) * b * d2 + d1 * d2) * dtype
    return KernelModel("rsl_grad_core", vmem, hbm, 2 * b * d1 * d2 + b * d1, grid)


def models():
    # Shapes = the shipped artifact shapes (see aot.py).
    return [
        gemv_model(1024, 512),
        gemv_t_model(1024, 512),
        reorth_model(1024, 64),
        gemm_model(1024, 1024, 1024),
        rsl_scores_model(32, 784, 256),
        rsl_grad_model(32, 784, 256),
    ]


def main() -> None:
    rows = []
    print(f"{'kernel':<14}{'grid':<14}{'VMEM/step':<12}{'AI (F/B)':<10}"
          f"{'bound':<9}{'roofline us':<12}")
    for km in models():
        assert km.vmem_per_step < VMEM_BYTES, f"{km.name} busts VMEM"
        print(
            f"{km.name:<14}{str(km.grid):<14}"
            f"{km.vmem_per_step / 1024:>8.1f} KiB "
            f"{km.intensity:>8.2f}  {km.bound:<9}{km.time_bound_us:>10.2f}"
        )
        rows.append(
            dict(
                kernel=km.name,
                grid=str(km.grid),
                vmem_per_step_bytes=km.vmem_per_step,
                hbm_bytes=km.hbm_bytes,
                flops=km.flops,
                arithmetic_intensity=round(km.intensity, 3),
                bound=km.bound,
                roofline_time_us=round(km.time_bound_us, 3),
            )
        )
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "l1_roofline.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(f"\nwrote {os.path.normpath(path)}")


if __name__ == "__main__":
    main()
