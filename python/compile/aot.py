"""AOT lowering: JAX/Pallas entry points -> HLO text artifacts + manifest.

Interchange format is HLO *text* (NOT serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly (see
/opt/xla-example/README.md). Every entry point returns a tuple and is
lowered with return_tuple=True; the rust side unwraps with to_tuple1/N.

Run once via `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Writes `<name>.hlo.txt` per entry plus `manifest.tsv` with one line per
artifact:  name <TAB> file <TAB> in_specs <TAB> out_specs
where a spec list is `;`-joined `dtype[dim,dim,...]` strings (rank-0 is
`dtype[]`). The rust `runtime::registry` parses exactly this format.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed shapes for the shipped artifact set. The GK shapes match the
# pjrt_matvec example and the runtime-backend integration test; the RSL
# shapes are the paper's MNIST(784) x USPS(256) with rank-5 manifold and
# batch 32.
GK_M, GK_N, GK_K = 1024, 512, 64
RSL_B, RSL_D1, RSL_D2 = 32, 784, 256

F32 = jnp.float32


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, F32)


def entries():
    """(name, fn, example_args) for every shipped artifact."""
    return [
        (
            f"gk_matvec_{GK_M}x{GK_N}",
            model.gk_matvec,
            (_spec((GK_M, GK_N)), _spec((GK_N,))),
        ),
        (
            f"gk_matvec_t_{GK_M}x{GK_N}",
            model.gk_matvec_t,
            (_spec((GK_M, GK_N)), _spec((GK_M,))),
        ),
        (
            f"gk_reorth_{GK_M}x{GK_K}",
            model.gk_reorth,
            (_spec((GK_M, GK_K)), _spec((GK_M,))),
        ),
        (
            f"gk_step_{GK_M}x{GK_N}k{GK_K}",
            model.gk_step,
            (
                _spec((GK_M, GK_N)),
                _spec((GK_N,)),
                _spec((GK_M,)),
                _spec(()),
                _spec((GK_M, GK_K)),
            ),
        ),
        (
            f"rsl_scores_b{RSL_B}_{RSL_D1}x{RSL_D2}",
            model.rsl_scores,
            (
                _spec((RSL_D1, RSL_D2)),
                _spec((RSL_B, RSL_D1)),
                _spec((RSL_B, RSL_D2)),
            ),
        ),
        (
            f"rsl_batch_grad_b{RSL_B}_{RSL_D1}x{RSL_D2}",
            model.rsl_batch_grad,
            (
                _spec((RSL_D1, RSL_D2)),
                _spec((RSL_B, RSL_D1)),
                _spec((RSL_B, RSL_D2)),
                _spec((RSL_B,)),
                _spec(()),
            ),
        ),
    ]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_specs(specs) -> str:
    out = []
    for s in specs:
        dims = ",".join(str(d) for d in s.shape)
        out.append(f"{s.dtype}[{dims}]")
    return ";".join(out)


def lower_entry(name, fn, args):
    """Lower one entry; returns (hlo_text, in_specs_str, out_specs_str)."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    out_shapes = jax.eval_shape(fn, *args)
    # Entries return tuples; normalize.
    if not isinstance(out_shapes, (tuple, list)):
        out_shapes = (out_shapes,)
    return text, _fmt_specs(args), _fmt_specs(out_shapes)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ns = ap.parse_args()
    os.makedirs(ns.out, exist_ok=True)

    manifest_lines = []
    for name, fn, args in entries():
        text, ins, outs = lower_entry(name, fn, args)
        fname = f"{name}.hlo.txt"
        path = os.path.join(ns.out, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name}\t{fname}\t{ins}\t{outs}")
        print(f"  lowered {name}: {len(text)} chars -> {fname}")

    mpath = os.path.join(ns.out, "manifest.tsv")
    with open(mpath, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {mpath} ({len(manifest_lines)} artifacts)")


if __name__ == "__main__":
    main()
