"""L1 Pallas kernel: fused classical Gram-Schmidt reorthogonalization.

Computes `w - Q @ (Q.T @ w)` — lines 6/13 of the paper's Algorithm 1 — as
TWO MXU contractions inside ONE pallas_call: the grid walks row-blocks of
Q twice (phase 0 accumulates c = Q.T @ w into a small VMEM-resident
coefficient vector, phase 1 emits w - Q @ c). Q is streamed from HBM
exactly twice and w once, the memory lower bound for this op. The
coefficient vector is carried as a second kernel output (k floats) rather
than scratch so the same code runs under interpret=True.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blk(dim, want):
    b = min(want, dim)
    while dim % b != 0:
        b -= 1
    return b


def _reorth_kernel(q_ref, w_ref, o_ref, c_ref):
    """Grid = (2, m/bm): phase 0 builds c = Q^T w, phase 1 o = w - Q c."""
    phase = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when((phase == 0) & (i == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    @pl.when(phase == 0)
    def _accumulate():
        c_ref[...] += q_ref[...].T @ w_ref[...]

    @pl.when(phase == 1)
    def _apply():
        o_ref[...] = w_ref[...] - q_ref[...] @ c_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m",))
def reorth(q, w, *, block_m: int = 512):
    """One CGS pass `w - Q (Q^T w)` for Q of shape (m, k), w of shape (m,)."""
    m, k = q.shape
    bm = _blk(m, block_m)
    grid = (2, m // bm)
    out, _c = pl.pallas_call(
        _reorth_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda p, i: (i, 0)),
            pl.BlockSpec((bm,), lambda p, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda p, i: (i,)),
            pl.BlockSpec((k,), lambda p, i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), q.dtype),
            jax.ShapeDtypeStruct((k,), q.dtype),
        ],
        interpret=True,
    )(q, w)
    return out
