"""L1 Pallas kernels: tiled GEMV and GEMV^T — the Golub-Kahan hot path.

TPU shaping (DESIGN.md §Hardware-Adaptation): the matrix is streamed
HBM→VMEM in (block_m x block_n) tiles expressed by BlockSpec; the vector
operand stays VMEM-resident; partial products accumulate in the output
block across the contraction grid dimension. Block sizes default to
multiples of the (8, 128) VPU lanes. `interpret=True` everywhere — the CPU
PJRT plugin cannot execute Mosaic custom-calls (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blk(dim, want):
    """Largest divisor of `dim` that is <= want (keeps grids exact)."""
    b = min(want, dim)
    while dim % b != 0:
        b -= 1
    return b


def _gemv_kernel(a_ref, x_ref, o_ref):
    """One (bm, bn) tile: o[bm] += A[bm, bn] @ x[bn]."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def gemv(a, x, *, block_m: int = 256, block_n: int = 512):
    """y = A @ x with a VMEM-tiled Pallas kernel."""
    m, n = a.shape
    bm = _blk(m, block_m)
    bn = _blk(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)


def _gemv_t_kernel(a_ref, y_ref, o_ref):
    """One (bm, bn) tile: o[bn] += A[bm, bn].T @ y[bm]."""
    i = pl.program_id(1)  # contraction dim is the second grid axis

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...].T @ y_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def gemv_t(a, y, *, block_m: int = 512, block_n: int = 256):
    """x = A.T @ y with a VMEM-tiled Pallas kernel.

    The grid iterates output blocks (axis 0) then contraction blocks
    (axis 1) so the accumulator block stays resident.
    """
    m, n = a.shape
    bm = _blk(m, block_m)
    bn = _blk(n, block_n)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        _gemv_t_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, y)
