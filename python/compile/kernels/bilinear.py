"""L1 Pallas kernels for the RSL application (paper §5).

* `rsl_scores`  — batched bilinear scores f_i = x_i^T W v_i. The batch of
  rank-1 bilinear forms is expressed as one MXU contraction (X @ W) and a
  row-wise reduction against V, tiled so W streams through VMEM in
  (d1-block x d2) panels.
* `rsl_grad_core` — the batch Euclidean hinge gradient's heavy term
  (X * g[:,None]).T @ V as a (b x d1)^T (b x d2) MXU contraction tiled over
  the (d1, d2) output — instead of b rank-1 updates (the GPU-native
  formulation), which is the hardware adaptation DESIGN.md describes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blk(dim, want):
    b = min(want, dim)
    while dim % b != 0:
        b -= 1
    return b


def _scores_kernel(x_ref, w_ref, v_ref, o_ref):
    """Grid over d1-blocks: accumulate f += sum((X_blk @ W_blk) * V)."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype)
    o_ref[...] += jnp.sum(s * v_ref[...], axis=1)


@functools.partial(jax.jit, static_argnames=("block_d1",))
def rsl_scores(w, xb, vb, *, block_d1: int = 256):
    """f_i = x_i^T W v_i for X (b, d1), W (d1, d2), V (b, d2)."""
    b, d1 = xb.shape
    d2 = vb.shape[1]
    bd1 = _blk(d1, block_d1)
    grid = (d1 // bd1,)
    return pl.pallas_call(
        _scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bd1), lambda j: (0, j)),
            pl.BlockSpec((bd1, d2), lambda j: (j, 0)),
            pl.BlockSpec((b, d2), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), w.dtype),
        interpret=True,
    )(xb, w, vb)


def _grad_kernel(x_ref, g_ref, v_ref, o_ref):
    """One (bd1, bd2) output tile: (X_blk * g).T @ V_blk."""
    xg = x_ref[...] * g_ref[...][:, None]
    o_ref[...] = jnp.dot(xg.T, v_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d1", "block_d2"))
def rsl_grad_core(xb, g, vb, *, block_d1: int = 256, block_d2: int = 256):
    """Gr_core = (X * g[:,None]).T @ V — (d1, d2) from (b, d1) and (b, d2)."""
    b, d1 = xb.shape
    d2 = vb.shape[1]
    bd1 = _blk(d1, block_d1)
    bd2 = _blk(d2, block_d2)
    grid = (d1 // bd1, d2 // bd2)
    return pl.pallas_call(
        _grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bd1), lambda i, j: (0, i)),
            pl.BlockSpec((b,), lambda i, j: (0,)),
            pl.BlockSpec((b, bd2), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bd1, bd2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d1, d2), xb.dtype),
        interpret=True,
    )(xb, g, vb)
