"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function here is the mathematical definition the corresponding kernel
in this package must reproduce; `python/tests/test_kernels.py` asserts
allclose between the two across shape/dtype sweeps (hypothesis-driven).
"""

import jax.numpy as jnp


def gemv(a, x):
    """y = A @ x."""
    return a @ x


def gemv_t(a, y):
    """x = A.T @ y."""
    return a.T @ y


def gemm(a, b):
    """C = A @ B."""
    return a @ b


def reorth(q, w):
    """One classical Gram-Schmidt pass: w - Q @ (Q.T @ w).

    This is lines 6/13 of the paper's Algorithm 1.
    """
    return w - q @ (q.T @ w)


def rsl_scores(w, xb, vb):
    """Bilinear scores f_i = x_i^T W v_i for a batch (paper eq. 19)."""
    return jnp.sum((xb @ w) * vb, axis=1)


def hinge_loss(f, y):
    """max(0, 1 - y*f)."""
    return jnp.maximum(0.0, 1.0 - y * f)


def rsl_batch_grad(w, xb, vb, y, lam):
    """Euclidean batch gradient of the regularized hinge objective.

    Gr = 1/b * sum_i hinge'(f_i, y_i) x_i v_i^T + lam * W
    hinge'(f, y) = -y on margin violation else 0.
    Returns (Gr, mean_loss). Mirrors rust `rsl::model::batch_euclidean_gradient`.
    """
    f = rsl_scores(w, xb, vb)
    loss = jnp.mean(hinge_loss(f, y))
    g = jnp.where(1.0 - y * f > 0.0, -y, 0.0) / xb.shape[0]
    gr = (xb * g[:, None]).T @ vb + lam * w
    return gr, loss
