"""L1 Pallas kernel: MXU-shaped tiled GEMM.

Classic (i, j, k) tiling: the (bm, bn) output tile accumulates over the k
grid axis while A- and B-tiles stream through VMEM. bf16 inputs with f32
accumulation are supported (`preferred_element_type`), matching the MXU
contract; tests check both f32 and bf16 tolerance bands.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blk(dim, want):
    b = min(want, dim)
    while dim % b != 0:
        b -= 1
    return b


def _gemm_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k")
)
def gemm(a, b, *, block_m: int = 128, block_n: int = 128, block_k: int = 256):
    """C = A @ B, accumulating in f32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"gemm: {a.shape} @ {b.shape}"
    bm = _blk(m, block_m)
    bn = _blk(n, block_n)
    bk = _blk(k, block_k)
    grid = (m // bm, n // bn, k // bk)
    out_dtype = jnp.promote_types(a.dtype, jnp.float32)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,
    )(a, b)
